"""Batched serving engines: LM decode (DecodeEngine) and solver pipelines
(PipelineEngine).

DecodeEngine is continuous-batching-lite: a fixed pool of B slots;
finished sequences free their slot and the next queued request is
prefilled into it.  The decode step is one jit'd SPMD program over the
whole pool (padded slots masked — implicit vector masking over the
request dimension).

PipelineEngine serves the registry's fused solver pipelines (5G-style
equalization traffic): jobs are grouped by problem shape, padded to the
lane-pool size, and dispatched as ONE pallas grid per group — the same
lane model the paper's REVEL uses for per-subcarrier matrices.
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import ArchConfig


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, cfg: ArchConfig, params, batch: int = 8,
                 max_len: int = 512, eos_id: int = 1, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.eos = eos_id
        self.cache = D.init_cache(cfg, batch, max_len)
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(
            lambda p, c, t, pos: D.decode_step(p, cfg, c, t, pos))
        self._queue: list[Request] = []
        self._slots: list[Request | None] = [None] * batch

    def submit(self, req: Request):
        self._queue.append(req)

    def _prefill_slot(self, slot: int, req: Request, tokens, pos):
        """Feed the prompt token-by-token through decode_step (correct,
        simple; a fused prefill kernel is the TPU fast path)."""
        for t in req.prompt[:-1]:
            tokens[slot] = t
            logits, self.cache = self._step(
                self.params, self.cache,
                jnp.asarray(tokens)[:, None],
                jnp.full((self.batch,), pos, jnp.int32))
            pos += 1
        tokens[slot] = req.prompt[-1]
        return pos

    def run(self) -> list[Request]:
        """Lockstep pool decode (uniform positions). Simplification: all
        pool members share a position counter; real deployments use
        per-slot positions + paged caches."""
        done: list[Request] = []
        while self._queue:
            active = self._queue[: self.batch]
            self._queue = self._queue[self.batch:]
            # pad the pool
            while len(active) < self.batch:
                active.append(Request(prompt=[self.eos], max_new=0))
            tokens = np.zeros((self.batch,), np.int64)
            plen = max(len(r.prompt) for r in active)
            # right-align prompts into the shared position stream
            toks = np.full((self.batch, plen), self.eos, np.int64)
            for i, r in enumerate(active):
                toks[i, plen - len(r.prompt):] = r.prompt
            pos = 0
            for j in range(plen - 1):
                _, self.cache = self._step(
                    self.params, self.cache, jnp.asarray(toks[:, j:j + 1]),
                    jnp.full((self.batch,), pos, jnp.int32))
                pos += 1
            cur = jnp.asarray(toks[:, -1:])
            max_new = max(r.max_new for r in active)
            for _ in range(max_new):
                logits, self.cache = self._step(
                    self.params, self.cache, cur,
                    jnp.full((self.batch,), pos, jnp.int32))
                pos += 1
                if any(r.temperature > 0 for r in active):
                    self.key, sub = jax.random.split(self.key)
                    nxt = jax.random.categorical(sub, logits)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                nxt_np = np.asarray(nxt)
                for i, r in enumerate(active):
                    if not r.done and len(r.out) < r.max_new:
                        tok = int(nxt_np[i])
                        r.out.append(tok)
                        if tok == self.eos:
                            r.done = True
                cur = nxt[:, None]
                if all(r.done or len(r.out) >= r.max_new for r in active):
                    break
            done.extend(r for r in active if r.max_new > 0)
            # fresh cache per pool generation (slot-level reuse is the
            # paged-cache extension)
            self.cache = D.init_cache(self.cfg, self.batch, self.max_len)
        return done


# ---------------- solver-pipeline serving ----------------

@dataclasses.dataclass
class SolveJob:
    """One solver problem: ``args`` are the per-problem arrays WITHOUT the
    batch dimension (e.g. cholesky_solve: (a (N,N), b (N,M)));
    ``out`` is filled by PipelineEngine.run()."""
    args: tuple
    out: np.ndarray | None = None


class PipelineEngine:
    """Batched solver service over a registered pipeline.

    Jobs are grouped by problem shape, stacked, padded to the ``lanes``
    pool size with identity problems (masked lanes — their results are
    discarded), and executed as one grid launch per group.  ``pipeline``
    is any ``kind="pipeline"`` name in the kernel registry; extra
    keyword ``options`` (e.g. ``sigma2`` for mmse_equalize) are bound
    into the served kernel.
    """

    def __init__(self, pipeline: str = "cholesky_solve", lanes: int = 8,
                 **options):
        from repro import kernels as K
        self.spec = K.get(pipeline)
        if self.spec.kind != "pipeline":
            raise ValueError(f"{pipeline!r} is a {self.spec.kind}, "
                             "not a servable pipeline")
        self.lanes = lanes
        self._queue: list[SolveJob] = []
        self._fn = jax.jit(functools.partial(self.spec.pallas, **options))

    def submit(self, job: SolveJob) -> SolveJob:
        self._queue.append(job)
        return job

    def _pad_group(self, stacked: list[np.ndarray]) -> list[np.ndarray]:
        """Pad the batch dim to a multiple of the lane count with benign
        problems (identity matrix / zero rhs) so padded lanes stay
        finite and cannot contaminate real lanes."""
        b = stacked[0].shape[0]
        pad = (-b) % self.lanes
        if pad == 0:
            return stacked
        out = []
        for arr in stacked:
            filler = np.zeros((pad,) + arr.shape[1:], arr.dtype)
            if filler.ndim == 3 and filler.shape[1] == filler.shape[2]:
                filler += np.eye(filler.shape[1], dtype=arr.dtype)
            out.append(np.concatenate([arr, filler], axis=0))
        return out

    def run(self) -> list[SolveJob]:
        done: list[SolveJob] = []
        groups: dict[tuple, list[SolveJob]] = collections.defaultdict(list)
        for job in self._queue:
            key = tuple(a.shape for a in job.args)
            groups[key].append(job)
        self._queue = []
        for jobs in groups.values():
            stacked = [np.stack([np.asarray(j.args[i]) for j in jobs])
                       for i in range(len(jobs[0].args))]
            padded = self._pad_group(stacked)
            res = np.asarray(self._fn(*[jnp.asarray(p) for p in padded]))
            for i, job in enumerate(jobs):
                job.out = res[i]
            done.extend(jobs)
        return done
