"""Adaptive flush thresholds: per-bucket ``max_wait`` and per-pool
pressure picked from observed traffic instead of hand-set constants.

The mux's continuous-batching knobs — how long a partial bucket may age
before flushing (``max_wait``) and how deep a pool's backlog may grow
before partials drain (``pressure``) — are tuning knobs in exactly the
Buttari-et-al. tiled-LA sense: the right value depends on measured
behavior (inter-arrival times, launch cost), not on anything knowable at
construction time.  :class:`BucketTuner` closes that loop from two
observation streams the serving stack already produces:

* **arrivals** — ``note_arrival`` maintains a per-(pipeline, bucket)
  EWMA of inter-arrival times.  The tuned per-bucket ``max_wait`` is
  the *expected time for the partial to fill*::

      max_wait = clamp(missing_lanes * ewma_interarrival,
                       wait_floor, wait_cap)

  A bucket with fast arrivals flushes stragglers quickly (if the group
  were going to fill, it would have filled by then — holding longer
  only adds latency); a slow bucket is allowed its expected fill time,
  capped so no job is held hostage to a dried-up stream.

* **launches** — ``note_launch`` maintains a per-pipeline EWMA of
  measured per-lane launch cost.  The tuned per-pool pressure is the
  backlog at which draining amortizes the launch overhead
  ``pressure_gain`` times over::

      pressure = clamp(pressure_gain * overhead / lane_cost,
                       lanes, pressure_cap_lanes * lanes)

  When overhead dominates lane cost (tiny problems), batches should be
  deep before partials drain; when lanes are expensive, holding a
  backlog buys nothing and partials drain early.

Until a stream has ``calibration_warmup`` observations the tuner
returns the configured defaults — the same warmup discipline as the
cost model.  Every constant above is a ``ServeConfig`` knob
(``REPRO_SERVE_ADAPT_THRESHOLDS`` masters the whole tuner; see
:mod:`repro.serve.config`).
"""
from __future__ import annotations

from repro.serve.config import global_config


class _Ewma:
    __slots__ = ("value", "count", "alpha")

    def __init__(self, alpha: float):
        self.value = 0.0
        self.count = 0
        self.alpha = float(alpha)

    def observe(self, x: float) -> None:
        x = float(x)
        if self.count == 0:
            self.value = x
        else:
            self.value += self.alpha * (x - self.value)
        self.count += 1


class BucketTuner:
    """Observed-traffic flush-threshold tuner (module docstring).

    ``cost_model`` supplies the launch-overhead estimate the pressure
    rule amortizes (falling back to the config default when absent); the
    tuner itself never prices anything else through it.
    """

    def __init__(self, lanes: int, config=None, cost_model=None):
        self.lanes = int(lanes)
        self.config = config if config is not None else global_config
        self.cost_model = cost_model
        self._interarrival: dict[tuple, _Ewma] = {}
        self._last_arrival: dict[tuple, float] = {}
        self._lane_cost: dict[str, _Ewma] = {}

    # ---------------- observation ----------------

    def note_arrival(self, pipeline: str, key: tuple, t: float) -> None:
        bkey = (pipeline, key)
        last = self._last_arrival.get(bkey)
        self._last_arrival[bkey] = t
        if last is None:
            return
        gap = t - last
        if gap < 0:
            return
        ewma = self._interarrival.get(bkey)
        if ewma is None:
            ewma = self._interarrival[bkey] = _Ewma(
                self.config.interarrival_alpha)
        ewma.observe(gap)

    def note_launch(self, pipeline: str, lanes: int,
                    measured: float) -> None:
        if measured is None or not measured > 0.0 or lanes < 1:
            return
        ewma = self._lane_cost.get(pipeline)
        if ewma is None:
            ewma = self._lane_cost[pipeline] = _Ewma(
                self.config.interarrival_alpha)
        ewma.observe(measured / lanes)

    # ---------------- tuned thresholds ----------------

    def max_wait(self, pipeline: str, key: tuple, queued: int,
                 default: float | None) -> float | None:
        """Tuned age threshold for a partial bucket holding ``queued``
        jobs, or ``default`` until the bucket's arrival stream has
        warmed up."""
        cfg = self.config
        ewma = self._interarrival.get((pipeline, key))
        if ewma is None or ewma.count < cfg.calibration_warmup:
            return default
        missing = max(1, self.lanes - queued % self.lanes)
        wait = missing * ewma.value
        cap = cfg.wait_cap if default is None else min(cfg.wait_cap,
                                                       default)
        return min(max(wait, cfg.wait_floor), cap)

    def pressure(self, pipeline: str, default: int) -> int:
        """Tuned per-pool pressure threshold, or ``default`` until the
        pipeline's launch-cost stream has warmed up."""
        cfg = self.config
        ewma = self._lane_cost.get(pipeline)
        if ewma is None or ewma.count < cfg.calibration_warmup:
            return default
        overhead = (self.cost_model.launch_overhead
                    if self.cost_model is not None
                    else cfg.overhead_floor)
        lane_cost = max(ewma.value, 1e-12)
        want = cfg.pressure_gain * overhead / lane_cost
        return int(min(max(want, self.lanes),
                       cfg.pressure_cap_lanes * self.lanes))
