"""Env-tunable serving configuration: one ``ServeConfig`` instance,
every calibration/tuning knob behind an environment variable.

The self-tuning cost model (:mod:`repro.serve.cost`) and the adaptive
flush-threshold tuner (:mod:`repro.serve.tuning`) both read their knobs
from the module-level :data:`global_config` — the alpa ``global_env.py``
pattern — so a deployment can pin or free every part of the calibration
loop without code edits::

    REPRO_SERVE_CALIBRATE=1 python -m repro.launch.serve_solvers --policy

Knob reference (name / env var / default / effect) — the same table is
kept in ROADMAP.md's serving notes:

========================  =================================  ========
attribute                 env var                            default
========================  =================================  ========
calibrate                 REPRO_SERVE_CALIBRATE              0 (off)
calibration_alpha         REPRO_SERVE_CALIBRATION_ALPHA      0.35
calibration_window        REPRO_SERVE_CALIBRATION_WINDOW     5
calibration_warmup        REPRO_SERVE_CALIBRATION_WARMUP     3
rate_floor                REPRO_SERVE_RATE_FLOOR             1e-15
overhead_floor            REPRO_SERVE_OVERHEAD_FLOOR         1e-9
drift_alert_ratio         REPRO_SERVE_DRIFT_ALERT_RATIO      1.5
bench_json                REPRO_SERVE_BENCH_JSON             BENCH_pipelines.json
adapt_thresholds          REPRO_SERVE_ADAPT_THRESHOLDS       0 (off)
interarrival_alpha        REPRO_SERVE_INTERARRIVAL_ALPHA     0.3
wait_floor                REPRO_SERVE_WAIT_FLOOR             0.0
wait_cap                  REPRO_SERVE_WAIT_CAP               5e-3
pressure_gain             REPRO_SERVE_PRESSURE_GAIN          8.0
pressure_cap_lanes        REPRO_SERVE_PRESSURE_CAP_LANES     8
mesh_size                 REPRO_SERVE_MESH_SIZE              1
shard_split_pressure      REPRO_SERVE_SHARD_SPLIT_PRESSURE   2.0
steal_ratio               REPRO_SERVE_STEAL_RATIO            1.0
imbalance_alert           REPRO_SERVE_IMBALANCE_ALERT        1.5
fault_trace               REPRO_SERVE_FAULT_TRACE            "" (off)
fault_seed                REPRO_SERVE_FAULT_SEED             0
max_retries               REPRO_SERVE_MAX_RETRIES            2
retry_backoff             REPRO_SERVE_RETRY_BACKOFF          1e-4
quarantine_after          REPRO_SERVE_QUARANTINE_AFTER       3
probe_after               REPRO_SERVE_PROBE_AFTER            3.0
demote_after              REPRO_SERVE_DEMOTE_AFTER           2
watchdog_ratio            REPRO_SERVE_WATCHDOG_RATIO         0.0 (off)
event_cap                 REPRO_SERVE_EVENT_CAP              100000
decode_slots              REPRO_SERVE_DECODE_SLOTS           4
decode_max_len            REPRO_SERVE_DECODE_MAX_LEN         128
decode_steps_per_poll     REPRO_SERVE_DECODE_STEPS_PER_POLL  8
========================  =================================  ========

* ``calibrate`` — master switch for ONLINE re-fitting: with it off, a
  ``CostModel`` built without an explicit ``adaptive=True`` stays
  frozen at its seeded/bench-calibrated rates (predictions are still
  compared against measurements and drift is still tracked whenever a
  model IS adaptive).  Off by default so replayable tests and committed
  golden traces price deterministically.
* ``calibration_alpha`` — EWMA weight of each new window-median; higher
  adapts faster, lower smooths more.
* ``calibration_window`` — samples per robust window; the estimator
  updates on the MEDIAN of each full window, so up to
  ``(window - 1) // 2`` outlier flushes per window cannot move it.
* ``calibration_warmup`` — window-median updates required before an
  online estimate replaces the seeded value (one weird first flush
  cannot repoint admission control).
* ``rate_floor`` / ``overhead_floor`` — positivity clamps (sec/FLOP,
  seconds): no measurement stream, however adversarial, can drive an
  estimate to zero or below.
* ``drift_alert_ratio`` — |log ratio| threshold above which a
  (pipeline, variant) pair is flagged ``alert`` in drift reports.
* ``bench_json`` — default path ``CostModel.from_bench_json`` reads.
* ``adapt_thresholds`` — master switch for the per-bucket flush tuner
  (``max_wait`` from observed inter-arrival, pool pressure from
  observed launch cost).  Off by default for the same determinism
  reason as ``calibrate``.
* ``interarrival_alpha`` — EWMA weight for per-bucket inter-arrival
  estimates.
* ``wait_floor`` / ``wait_cap`` — clamp (seconds) on the tuned
  per-bucket ``max_wait``.
* ``pressure_gain`` — tuned pressure aims to amortize the launch
  overhead ``pressure_gain`` times over a drain's lane time.
* ``pressure_cap_lanes`` — tuned pressure never exceeds this many
  multiples of the pool width (and never drops below one pool width).
* ``mesh_size`` — default lane-shard count for :class:`SolverMux`
  instances built without an explicit ``mesh_size``: 1 keeps the
  single-device path (bit-identical to the pre-mesh stack); N > 1
  spans each pool's lane axis over the first N local devices via
  ``distributed.sharding.shard_map`` (aggregate capacity
  ``lanes * mesh_size``).
* ``shard_split_pressure`` — a shape bucket whose backlog reaches
  ``shard_split_pressure * lanes`` jobs is *hot*: the mux offers it as
  mesh-spanning sharded flushes (cross-shard work stealing) instead of
  serial per-shard launches, subject to the cost comparison below.
* ``steal_ratio`` — safety margin on the steal pricing: a hot bucket
  splits across shards only when ``sharded_cost * steal_ratio <
  local_cost`` (the serial per-shard launches it replaces), so stealing
  never beats a cheaper local partial.  1.0 = pure cost comparison;
  > 1.0 biases toward local launches.
* ``imbalance_alert`` — per-shard lane-load imbalance ratio
  (max/mean dispatched lanes) above which ``MetricsSnapshot`` flags
  ``shard_imbalance_alert``.
* ``fault_trace`` — path to a JSON fault trace for
  :class:`repro.serve.faults.FaultInjector`; empty (the default) means
  no injector is built and every serving path is bit-identical to the
  fault-free stack (golden traces stay pinned).
* ``fault_seed`` — seed keying the injector's per-attempt rng streams
  (a ``seed`` field inside the trace file wins).
* ``max_retries`` — supervised relaunch attempts per failed group
  beyond the first try.  Hard-deadline jobs are ALWAYS retried to this
  bound; a best-effort group whose retries exhaust is failed with a
  structured reason rather than silently dropped.
* ``retry_backoff`` — base of the bounded exponential backoff charged
  (in seconds of launch budget) against the failing group's shard for
  each retry: retry k debits ``retry_backoff * 2**k``.  The debit
  starves the admission budget, not the wall-clock — replays stay
  deterministic.
* ``quarantine_after`` — consecutive launch failures on one shard
  before :class:`LaneShards` quarantines it (placement stops, capacity
  shrinks, the CostModel re-prices spanning launches at the reduced
  mesh).
* ``probe_after`` — scheduling-clock seconds a quarantined shard sits
  out before the mux routes a single probe launch at it; a surviving
  probe reinstates the shard, a failing one re-arms the timer.
* ``demote_after`` — consecutive supervised-launch failures of one
  (pipeline, variant, shape-bucket) before ``VariantDispatcher``
  demotes that bucket down the ladder (tiled → blocked → base) with a
  ``demote`` event and a drift-style alert.  Only variants that share
  the spec's calling convention (``variant.filler is None``) demote.
* ``watchdog_ratio`` — a launch whose measured wall exceeds
  ``watchdog_ratio x`` the CostModel's prediction emits a ``watchdog``
  event and counts against shard health.  0 (the default) disables the
  watchdog: it compares real wall-clock against predictions, which is
  machine-dependent — leaving it off keeps golden traces bit-exact.
* ``event_cap`` — ring-buffer bound on ``mux.events``; once the cap is
  hit the oldest events are dropped (``drain_events()`` reports how
  many) so a long-running serve loop cannot leak memory through its
  event log.
* ``decode_slots`` — default continuous-batching slot count (the pool
  width) for :class:`repro.serve.decode.DecodeEngine` instances built
  by the trace-replay / benchmark entry points.
* ``decode_max_len`` — default per-slot KV-cache length (tokens) for
  the same entry points; a request's ``max_new`` is clamped so prompt
  plus output always fits its slot's pages.
* ``decode_steps_per_poll`` — how many continuous-batching decode
  steps one ``SolverMux.poll()`` runs at most once a decode engine is
  attached: the fairness lever between token traffic and solver
  flushes on the shared front-end (``run()`` drains are unbounded).
"""
from __future__ import annotations

import os


def _env_bool(name: str, default: bool) -> bool:
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.strip().lower() in ("1", "true", "yes", "on")


def _env_float(name: str, default: float) -> float:
    raw = os.environ.get(name)
    return default if raw is None else float(raw)


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name)
    return default if raw is None else int(raw)


class ServeConfig:
    """All serving-stack tuning knobs (see the module docstring for the
    per-knob reference).  Construction reads the environment once;
    :meth:`reload` re-reads it (tests use this around ``monkeypatch``).
    """

    def __init__(self):
        self.reload()

    def reload(self) -> "ServeConfig":
        # ---- online cost-model calibration ----
        self.calibrate = _env_bool("REPRO_SERVE_CALIBRATE", False)
        self.calibration_alpha = _env_float(
            "REPRO_SERVE_CALIBRATION_ALPHA", 0.35)
        self.calibration_window = _env_int(
            "REPRO_SERVE_CALIBRATION_WINDOW", 5)
        self.calibration_warmup = _env_int(
            "REPRO_SERVE_CALIBRATION_WARMUP", 3)
        self.rate_floor = _env_float("REPRO_SERVE_RATE_FLOOR", 1e-15)
        self.overhead_floor = _env_float(
            "REPRO_SERVE_OVERHEAD_FLOOR", 1e-9)
        self.drift_alert_ratio = _env_float(
            "REPRO_SERVE_DRIFT_ALERT_RATIO", 1.5)
        self.bench_json = os.environ.get(
            "REPRO_SERVE_BENCH_JSON", "BENCH_pipelines.json")
        # ---- adaptive flush thresholds ----
        self.adapt_thresholds = _env_bool(
            "REPRO_SERVE_ADAPT_THRESHOLDS", False)
        self.interarrival_alpha = _env_float(
            "REPRO_SERVE_INTERARRIVAL_ALPHA", 0.3)
        self.wait_floor = _env_float("REPRO_SERVE_WAIT_FLOOR", 0.0)
        self.wait_cap = _env_float("REPRO_SERVE_WAIT_CAP", 5e-3)
        self.pressure_gain = _env_float("REPRO_SERVE_PRESSURE_GAIN", 8.0)
        self.pressure_cap_lanes = _env_int(
            "REPRO_SERVE_PRESSURE_CAP_LANES", 8)
        # ---- mesh-sharded lane pools ----
        self.mesh_size = _env_int("REPRO_SERVE_MESH_SIZE", 1)
        self.shard_split_pressure = _env_float(
            "REPRO_SERVE_SHARD_SPLIT_PRESSURE", 2.0)
        self.steal_ratio = _env_float("REPRO_SERVE_STEAL_RATIO", 1.0)
        self.imbalance_alert = _env_float(
            "REPRO_SERVE_IMBALANCE_ALERT", 1.5)
        # ---- fault injection + launch supervision ----
        self.fault_trace = os.environ.get("REPRO_SERVE_FAULT_TRACE", "")
        self.fault_seed = _env_int("REPRO_SERVE_FAULT_SEED", 0)
        self.max_retries = _env_int("REPRO_SERVE_MAX_RETRIES", 2)
        self.retry_backoff = _env_float(
            "REPRO_SERVE_RETRY_BACKOFF", 1e-4)
        self.quarantine_after = _env_int(
            "REPRO_SERVE_QUARANTINE_AFTER", 3)
        self.probe_after = _env_float("REPRO_SERVE_PROBE_AFTER", 3.0)
        self.demote_after = _env_int("REPRO_SERVE_DEMOTE_AFTER", 2)
        self.watchdog_ratio = _env_float(
            "REPRO_SERVE_WATCHDOG_RATIO", 0.0)
        self.event_cap = _env_int("REPRO_SERVE_EVENT_CAP", 100000)
        # ---- continuous-batching decode ----
        self.decode_slots = _env_int("REPRO_SERVE_DECODE_SLOTS", 4)
        self.decode_max_len = _env_int("REPRO_SERVE_DECODE_MAX_LEN", 128)
        self.decode_steps_per_poll = _env_int(
            "REPRO_SERVE_DECODE_STEPS_PER_POLL", 8)
        return self


global_config = ServeConfig()
