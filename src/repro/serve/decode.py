"""LM decode serving: :class:`Request` + :class:`DecodeEngine`.

DecodeEngine is continuous-batching-lite on top of
:class:`repro.serve.core.EngineCore`: a fixed pool of ``batch`` lanes
(slots); queued requests are taken a pool at a time, prompts
right-aligned into a shared position stream, and the decode step is one
jit'd SPMD program over the whole pool (padded slots masked — implicit
vector masking over the request dimension).  The shared core supplies
the queue, the clock and the lane/latency accounting, so decode traffic
reports the same SLO metrics surface as the solver engines.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as D
from repro.models.config import ArchConfig
from repro.serve.core import FifoEngineCore


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float | None = None
    finished_at: float | None = None


class DecodeEngine(FifoEngineCore):
    def __init__(self, cfg: ArchConfig, params, batch: int = 8,
                 max_len: int = 512, eos_id: int = 1, seed: int = 0,
                 clock=None):
        super().__init__(batch, clock=clock)
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.eos = eos_id
        self.cache = D.init_cache(cfg, self.lanes, max_len)
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(
            lambda p, c, t, pos: D.decode_step(p, cfg, c, t, pos))

    def run(self) -> list[Request]:
        """Lockstep pool decode (uniform positions). Simplification: all
        pool members share a position counter; real deployments use
        per-slot positions + paged caches."""
        done: list[Request] = []
        while self.pending():
            active = self.take(self.lanes)
            n_real = len(active)
            # pad the pool
            while len(active) < self.lanes:
                active.append(Request(prompt=[self.eos], max_new=0))
            plen = max(len(r.prompt) for r in active)
            # right-align prompts into the shared position stream
            toks = np.full((self.lanes, plen), self.eos, np.int64)
            for i, r in enumerate(active):
                toks[i, plen - len(r.prompt):] = r.prompt
            pos = 0
            for j in range(plen - 1):
                _, self.cache = self._step(
                    self.params, self.cache, jnp.asarray(toks[:, j:j + 1]),
                    jnp.full((self.lanes,), pos, jnp.int32))
                pos += 1
            cur = jnp.asarray(toks[:, -1:])
            max_new = max(r.max_new for r in active)
            for _ in range(max_new):
                logits, self.cache = self._step(
                    self.params, self.cache, cur,
                    jnp.full((self.lanes,), pos, jnp.int32))
                pos += 1
                if any(r.temperature > 0 for r in active):
                    self.key, sub = jax.random.split(self.key)
                    nxt = jax.random.categorical(sub, logits)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                nxt_np = np.asarray(nxt)
                for i, r in enumerate(active):
                    if not r.done and len(r.out) < r.max_new:
                        tok = int(nxt_np[i])
                        r.out.append(tok)
                        if tok == self.eos:
                            r.done = True
                cur = nxt[:, None]
                if all(r.done or len(r.out) >= r.max_new for r in active):
                    break
            self.record_launch("decode", ("pool", self.lanes),
                               n_real, self.lanes - n_real)
            for r in active[:n_real]:
                if r.max_new > 0:
                    self.record_job("decode", r)
                    done.append(r)
            # fresh cache per pool generation (slot-level reuse is the
            # paged-cache extension)
            self.cache = D.init_cache(self.cfg, self.lanes, self.max_len)
        return done
