"""LM decode serving: :class:`Request` + :class:`DecodeEngine`.

DecodeEngine is a continuous-batching engine on top of
:class:`repro.serve.core.FifoEngineCore`: a fixed pool of ``batch``
lanes (slots), each carrying its own position counter.  Every
:meth:`DecodeEngine.step` is ONE jit'd SPMD program over the whole pool
(the paper's implicit vector masking applied to the request dimension):
slots mid-prefill consume their next prompt token, generating slots
consume their last output token, idle slots are fed a benign token at
position 0 and their logits discarded.  A finishing request frees only
its slot; the next queued request prefills into that slot while the
other slots keep generating — no pool-wide barrier, no cache rebuild.

Slot-level paged KV reuse: :func:`repro.models.decode.attention_decode`
masks each slot's attention to its live length ``pos + 1``, so a freed
slot is reused by simply resetting its position to 0 — the new
request's tokens overwrite the slot's cache pages sequentially and the
stale tail beyond the live position is never read.  (The
non-contamination characterization test in ``tests/test_decode_serve.py``
pins exactly this property.)

Sampling is per-slot: each request derives its own RNG stream via
``fold_in(base_key, request.seq)`` folded again with the request's own
output index, and ``argmax``/``categorical`` is selected per slot — a
greedy request never consumes RNG state, so its output is independent
of what its pool-mates do.  (The old lockstep path, preserved verbatim
as :meth:`DecodeEngine.run_lockstep`, switched the WHOLE pool to one
shared ``categorical`` stream whenever any pool member sampled; the
regression test pins both behaviors.)

The shared core supplies the queue, the clock and the lane/latency
accounting, so decode traffic reports the same SLO metrics surface as
the solver engines; per-phase (insert / prefill / generate) samples
land in :class:`repro.serve.metrics.DecodeStats`.  When attached to a
:class:`repro.serve.mux.SolverMux` the engine additionally shares the
mux's recorder, clocks and event stream (``event_cb``) and feeds
measured step wall-clock to the cost model (``observe_cb``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as D
from repro.models.config import ArchConfig
from repro.serve.core import FifoEngineCore


@dataclasses.dataclass
class Request:
    """One decode request.  ``priority``/``deadline`` use the same
    admission classes as :class:`repro.serve.mux.SolveJob` ("hard" is
    never shed); ``seq`` is assigned at submit (by the mux when
    attached) and seeds the request's private RNG stream."""
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    priority: str = "best_effort"
    deadline: float | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    dropped: bool = False
    submitted_at: float | None = None
    inserted_at: float | None = None
    finished_at: float | None = None
    seq: int | None = None


class DecodeEngine(FifoEngineCore):
    def __init__(self, cfg: ArchConfig, params, batch: int = 8,
                 max_len: int = 512, eos_id: int = 1, seed: int = 0,
                 clock=None):
        super().__init__(batch, clock=clock)
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.eos = eos_id
        self.cache = D.init_cache(cfg, self.lanes, max_len)
        self.key = jax.random.PRNGKey(seed)
        self._step_fn = jax.jit(
            lambda p, c, t, pos: D.decode_step(p, cfg, c, t, pos))
        self._sample_fn = jax.jit(jax.vmap(
            lambda k, l, t: jax.random.categorical(
                k, l / jnp.maximum(t, 1e-6))))
        # the servable decode spec: phase names + closed-form per-token
        # FLOPs, the unit the mux prices decode steps in
        from repro import kernels as K
        self.spec = K.get_decode("lm_decode")
        self.token_flops = self.spec.token_flops(cfg)
        # per-slot continuous-batching state
        self._slot_req: list[Request | None] = [None] * self.lanes
        self._slot_fed = [0] * self.lanes     # tokens fed == position
        self._slot_dirty = [False] * self.lanes  # held a prior request
        self._slot_wall0 = [0.0] * self.lanes    # insert wall stamp
        self._slot_gen0 = [0.0] * self.lanes     # first-output stamp
        self.steps = 0                # SPMD steps executed (both paths)
        self.tokens = 0               # tokens generated (both paths)
        self._serial = 0
        # mux attachment hooks (None when the engine runs standalone)
        self.event_cb = None          # (kind, t, **fields)
        self.observe_cb = None        # (phase, flops, measured_seconds)

    # ---------------- submission / queue state ----------------

    def submit(self, item: Request) -> Request:
        if not item.prompt:
            raise ValueError("decode request needs a non-empty prompt")
        if len(item.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of {len(item.prompt)} tokens does not fit the "
                f"{self.max_len}-token cache")
        # a request can never outgrow its slot's cache pages
        item.max_new = min(item.max_new, self.max_len - len(item.prompt))
        if item.seq is None:
            self._serial += 1
            item.seq = self._serial
        return super().submit(item)

    def occupied(self) -> int:
        """Slots currently holding an unfinished request."""
        return sum(r is not None for r in self._slot_req)

    def has_work(self) -> bool:
        return bool(self.pending() or self.occupied())

    def hard_waiting(self) -> bool:
        """Any hard-deadline request queued or in flight (the overload
        policy never defers decode while this holds)."""
        return any(r.priority == "hard" for r in self._queue) or any(
            r is not None and r.priority == "hard" for r in self._slot_req)

    def shed_expired(self, now: float) -> list[Request]:
        """Drop queued best-effort requests whose deadline has passed.
        Hard-deadline requests are never shed, and a request already
        holding a slot is never shed mid-stream."""
        keep, shed = [], []
        for r in self._queue:
            if (r.priority != "hard" and r.deadline is not None
                    and r.deadline < now):
                r.dropped = True
                r.finished_at = now
                shed.append(r)
            else:
                keep.append(r)
        self._queue = keep
        return shed

    # ---------------- continuous batching ----------------

    def _finish(self, r: Request, slot: int | None, now: float,
                done: list) -> None:
        r.done = True
        self.recorder.record_decode_request()
        self.record_job("decode", r)
        if self.event_cb is not None:
            self.event_cb("decode_done", now, seq=r.seq,
                          tokens=len(r.out))
        if slot is not None:
            self._slot_req[slot] = None
        done.append(r)

    def _insert_waiting(self, done: list) -> None:
        """Fill free slots oldest-first from the FIFO.  Slot reuse is
        the paged-cache move: position resets to 0 and the incoming
        request's tokens overwrite the slot's pages sequentially — the
        stale tail past the live position is masked by construction, so
        no cache zeroing happens here."""
        now = self.clock()
        for i in range(self.lanes):
            while self._slot_req[i] is None and self.pending():
                r = self.take(1)[0]
                r.inserted_at = now
                reused = self._slot_dirty[i]
                self._slot_dirty[i] = True
                self.recorder.record_decode_insert(reused)
                self.recorder.record_decode_phase(
                    "insert", now - r.submitted_at)
                if self.event_cb is not None:
                    self.event_cb("decode_insert", now, slot=i, seq=r.seq,
                                  prompt=len(r.prompt), max_new=r.max_new,
                                  priority=r.priority, reused=reused)
                if r.max_new <= 0:
                    self._finish(r, None, now, done)
                    continue
                self._slot_req[i] = r
                self._slot_fed[i] = 0
                self._slot_wall0[i] = self.wall()
                self._slot_gen0[i] = self._slot_wall0[i]

    def step(self) -> list[Request]:
        """One continuous-batching SPMD step: admit queued requests into
        free slots, feed every active slot its next token at its OWN
        position, select the next token per slot, retire finished
        requests.  Returns the requests that finished this step."""
        done: list[Request] = []
        self._insert_waiting(done)
        active = [i for i in range(self.lanes)
                  if self._slot_req[i] is not None]
        if not active:
            return done
        toks = np.zeros((self.lanes, 1), np.int32)
        pos = np.zeros((self.lanes,), np.int32)
        temps = np.zeros((self.lanes,), np.float32)
        for i in active:
            r, f = self._slot_req[i], self._slot_fed[i]
            toks[i, 0] = r.prompt[f] if f < len(r.prompt) else r.out[-1]
            pos[i] = f
            temps[i] = r.temperature
        t0 = self.wall()
        logits, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos))
        greedy = jnp.argmax(logits, axis=-1)
        if np.any(temps > 0):
            # per-slot RNG: each sampling request folds its own seq and
            # output index into the base key — pool-mates share nothing
            keys = np.zeros((self.lanes, 2), np.uint32)
            for i in active:
                r = self._slot_req[i]
                if r.temperature > 0:
                    keys[i] = np.asarray(jax.random.fold_in(
                        jax.random.fold_in(self.key, int(r.seq or 0)),
                        len(r.out)), np.uint32)
            sampled = self._sample_fn(
                jnp.asarray(keys), logits, jnp.asarray(temps))
            nxt = jnp.where(jnp.asarray(temps) > 0, sampled, greedy)
        else:
            # all-greedy step: no RNG op runs, no key is consumed
            nxt = greedy
        nxt_np = np.asarray(nxt)
        dt = self.wall() - t0
        now = self.clock()
        made = prompt_feeds = 0
        for i in active:
            r, f = self._slot_req[i], self._slot_fed[i]
            self._slot_fed[i] = f + 1
            if f < len(r.prompt) - 1:
                # mid-prefill: logits discarded, next prompt token next
                prompt_feeds += 1
                continue
            if f == len(r.prompt) - 1:
                # this step consumed the final prompt token and its
                # logits are the first output token: prefill is done
                self.recorder.record_decode_phase(
                    "prefill", self.wall() - self._slot_wall0[i])
                self._slot_gen0[i] = self.wall()
            tok = int(nxt_np[i])
            r.out.append(tok)
            made += 1
            if tok == self.eos or len(r.out) >= r.max_new:
                self.recorder.record_decode_phase(
                    "generate", self.wall() - self._slot_gen0[i])
                self._finish(r, i, now, done)
        self.steps += 1
        self.tokens += made
        self.recorder.record_decode_step(made)
        self.record_launch("decode", ("step", self.lanes), len(active),
                           self.lanes - len(active), measured=dt)
        if self.observe_cb is not None:
            phase = ("prefill" if prompt_feeds > len(active) - prompt_feeds
                     else "generate")
            self.observe_cb(phase, len(active) * self.token_flops, dt)
        return done

    def run(self) -> list[Request]:
        """Drain continuously: step until the queue and every slot are
        empty.  Unlike the lockstep baseline there is no pool barrier —
        freed slots re-admit queued requests between steps."""
        done: list[Request] = []
        while self.has_work():
            done.extend(self.step())
        return done

    # ---------------- preserved lockstep baseline ----------------

    def run_lockstep(self) -> list[Request]:
        """The original lockstep pool decode, preserved verbatim as the
        measured baseline (and for the single-request bit-identity
        characterization): all pool members share ONE position counter,
        prompts are right-aligned, the pool runs to the LONGEST member,
        and the cache is rebuilt between pool generations.  It also
        keeps the historical pool-wide sampling behavior — any sampling
        member switches the whole pool to one shared categorical stream
        — which the per-slot path above fixes."""
        done: list[Request] = []
        while self.pending():
            active = self.take(self.lanes)
            n_real = len(active)
            # pad the pool
            while len(active) < self.lanes:
                active.append(Request(prompt=[self.eos], max_new=0))
            plen = max(len(r.prompt) for r in active)
            # right-align prompts into the shared position stream
            toks = np.full((self.lanes, plen), self.eos, np.int64)
            for i, r in enumerate(active):
                toks[i, plen - len(r.prompt):] = r.prompt
            pos = 0
            for j in range(plen - 1):
                _, self.cache = self._step_fn(
                    self.params, self.cache, jnp.asarray(toks[:, j:j + 1]),
                    jnp.full((self.lanes,), pos, jnp.int32))
                pos += 1
                self.steps += 1
            cur = jnp.asarray(toks[:, -1:])
            max_new = max(r.max_new for r in active)
            for _ in range(max_new):
                logits, self.cache = self._step_fn(
                    self.params, self.cache, cur,
                    jnp.full((self.lanes,), pos, jnp.int32))
                pos += 1
                self.steps += 1
                if any(r.temperature > 0 for r in active):
                    self.key, sub = jax.random.split(self.key)
                    nxt = jax.random.categorical(sub, logits)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                nxt_np = np.asarray(nxt)
                for i, r in enumerate(active):
                    if not r.done and len(r.out) < r.max_new:
                        tok = int(nxt_np[i])
                        r.out.append(tok)
                        self.tokens += 1
                        if tok == self.eos:
                            r.done = True
                cur = nxt[:, None]
                if all(r.done or len(r.out) >= r.max_new for r in active):
                    break
            self.record_launch("decode", ("pool", self.lanes),
                               n_real, self.lanes - n_real)
            for r in active[:n_real]:
                if r.max_new > 0:
                    r.done = True
                    self.record_job("decode", r)
                    done.append(r)
            # fresh cache per pool generation (slot-level reuse is the
            # continuous path's paged-cache move)
            self.cache = D.init_cache(self.cfg, self.lanes, self.max_len)
        return done
