"""Serving stack for the FGOP reproduction.

Layout (one concern per module, all sharing the ``EngineCore`` queue +
lane-pool accounting + batch lifecycle):

  core     EngineCore (+ FifoEngineCore), ManualClock, registry-driven
           pad_group
  decode   DecodeEngine / Request       (LM continuous batching:
                                         per-slot positions, paged KV
                                         slot reuse, per-slot sampling;
                                         attaches to SolverMux)
  solver   PipelineEngine / SolveJob    (single solver pipeline)
  mux      SolverMux / OverloadPolicy   (mixed pipelines, shape-bucketed
                                         continuous batching, deadline-
                                         aware flush; admission control,
                                         preemption, coalescing)
  cost     CostModel / DriftStat        (self-tuning launch pricing:
                                         offline calibration from
                                         BENCH_pipelines.json + online
                                         re-fit from measured launches,
                                         drift observability)
  config   ServeConfig / global_config  (REPRO_SERVE_* env-tunable knobs
                                         for calibration + thresholds)
  tuning   BucketTuner                  (observed-traffic flush
                                         thresholds: max_wait, pressure)
  metrics  SLO dataclasses: p50/p99 latency (overall + per priority),
           throughput, lane utilization, padded-lane waste, dropped/
           preempted/coalesced counters, per-shard utilization
  shard    LaneShards                   (mesh-sharded lane pools:
                                         shard_map wrapping, placement,
                                         per-shard load accounting +
                                         quarantine/probe health)
  faults   FaultInjector                (seeded fault injection driving
                                         the launch-supervision /
                                         quarantine / demotion paths)
  engine   back-compat shim re-exporting the original names

The kernel registry (``repro.kernels``) is the routing table: any
``kind="pipeline"`` spec is servable, and its declared ``filler``
supplies benign padding lanes.
"""
from repro.serve.config import ServeConfig, global_config  # noqa: F401
from repro.serve.core import (EngineCore, FifoEngineCore,  # noqa: F401
                              ManualClock, pad_group)
from repro.serve.cost import (CostModel, DriftStat,  # noqa: F401
                              RobustEstimator)
from repro.serve.faults import (Fault, FaultInjector,  # noqa: F401
                                InjectedLaunchError)
from repro.serve.metrics import (DagStats, DecodeStats,  # noqa: F401
                                 DropRecord, FailRecord, FaultStats,
                                 LatencyStats, LaunchRecord,
                                 MetricsSnapshot, PipelineStats, Recorder,
                                 ShardStats, shard_stats)
from repro.serve.mux import DagJob, OverloadPolicy, SolverMux  # noqa: F401
from repro.serve.shard import LaneShards  # noqa: F401
from repro.serve.solver import (PipelineEngine, SolveJob,  # noqa: F401
                                VariantDispatcher)
from repro.serve.tuning import BucketTuner  # noqa: F401


def __getattr__(name):
    # decode pulls in the whole repro.models transformer stack; load it
    # lazily (PEP 562) so solver-only consumers don't pay for it
    if name in ("DecodeEngine", "Request"):
        from repro.serve import decode
        return getattr(decode, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "EngineCore", "FifoEngineCore", "ManualClock", "pad_group",
    "DecodeEngine", "Request",
    "PipelineEngine", "SolveJob", "SolverMux", "VariantDispatcher",
    "DagJob", "DagStats", "DecodeStats",
    "OverloadPolicy", "CostModel", "DriftStat", "RobustEstimator",
    "ServeConfig", "global_config", "BucketTuner",
    "DropRecord", "FailRecord", "FaultStats", "LatencyStats",
    "LaunchRecord", "MetricsSnapshot",
    "PipelineStats", "Recorder", "ShardStats", "shard_stats",
    "LaneShards", "Fault", "FaultInjector", "InjectedLaunchError",
]
