"""Common engine core shared by decode and solver serving.

Both engine families (LM decode and solver pipelines) are the same
machine at this altitude: submitted work, a fixed pool of ``lanes`` the
device executes in lockstep, and a batch lifecycle of *take → pad to
the pool → dispatch → scatter results → record metrics*.
:class:`EngineCore` owns the shared clock, lane-pool accounting (a
:class:`repro.serve.metrics.Recorder`), and group-dispatch lifecycle;
:class:`FifoEngineCore` adds the single-FIFO queue used by
``DecodeEngine`` and ``PipelineEngine`` (``SolverMux`` keeps
per-pipeline shape buckets instead), so each engine only implements
what actually differs: how a batch is executed.

Padding is registry-driven: a lane group short of the pool size is
filled from the pipeline's declared ``KernelSpec.filler`` — a benign
per-lane problem (identity system, zero right-hand side) whose result
is discarded.  There is deliberately no shape-sniffing fallback here;
a spec that wants to be served padded must declare its filler.
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.metrics import MetricsSnapshot, Recorder


class ManualClock:
    """Deterministic clock for tests and trace replays: ``clock()``
    returns the current virtual time; ``advance()`` moves it."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t

    def __call__(self) -> float:
        return self.t


class EngineCore:
    """Lane-pool accounting + batch lifecycle, engine-agnostic.

    ``lanes`` is the lockstep pool width (decode: slot count; solvers:
    grid lanes per launch).  ``clock`` is any zero-arg callable returning
    seconds — ``time.monotonic`` by default, :class:`ManualClock` in
    tests/replays.  Engines call :meth:`record_launch` /
    :meth:`record_job` as batches complete and expose :meth:`metrics`.

    ``wall`` is the *measurement* clock (``time.perf_counter`` by
    default) used by :meth:`_timed_call` to stamp real launch wall-clock
    onto every :class:`~repro.serve.metrics.LaunchRecord` — deliberately
    separate from the scheduling ``clock`` so virtual-clock replays still
    measure true execution cost.  Each measured launch is also fed to
    :meth:`observe_launch`, the hook engines override to close the
    cost-model calibration loop (the base hook is a no-op).

    Deliberately queue-free: single-FIFO engines (decode, one-pipeline
    solver) add the queue via :class:`FifoEngineCore`; the mux keeps its
    own per-pipeline shape buckets instead.
    """

    def __init__(self, lanes: int, clock=None, wall=None):
        if lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        self.lanes = int(lanes)
        self.clock = clock if clock is not None else time.monotonic
        self.wall = wall if wall is not None else time.perf_counter
        self.recorder = Recorder()
        # optional repro.serve.faults.FaultInjector: None (the default)
        # keeps every launch path bit-identical to the uninjected stack
        self.injector = None

    # ---------------- accounting ----------------

    def record_launch(self, pipeline: str, shape: tuple, real: int,
                      padded: int, variant: str = "base",
                      coalesced: int = 0, measured: float = None,
                      mesh: int = 1, shard: int = 0) -> None:
        self.recorder.record_launch(
            pipeline, shape, real, padded, self.clock(), variant,
            coalesced, math.nan if measured is None else measured,
            mesh, shard)

    def record_job(self, pipeline: str, item) -> None:
        """Stamp ``finished_at`` and log the job's latency sample (keyed
        by the item's priority class when it declares one)."""
        item.finished_at = self.clock()
        self.recorder.record_job(pipeline, item.submitted_at,
                                 item.finished_at,
                                 getattr(item, "priority", "best_effort"))

    def metrics(self) -> MetricsSnapshot:
        return self.recorder.snapshot()

    def reset_metrics(self) -> None:
        self.recorder.reset()

    def _timed_call(self, fn, padded: list, device=None,
                    fault_ctx: dict | None = None
                    ) -> tuple[np.ndarray, float]:
        """Execute one padded lane-group launch and measure its wall
        clock on ``self.wall``.  The one seam every launch goes through:
        deterministic tests replace it with a synthetic wall model to
        drive the calibration loop without real-timer noise.

        ``device`` commits the inputs to one mesh shard's device before
        the call (mesh-sharded muxes placing a non-spanning launch);
        ``None`` keeps the legacy default-device path untouched.

        ``fault_ctx`` identifies the attempt to an attached
        :class:`repro.serve.faults.FaultInjector` (``self.injector``):
        a drawn ``raise`` fault aborts BEFORE the kernel executes
        (:class:`~repro.serve.faults.InjectedLaunchError` — failed
        attempts cost no kernel time), a ``nan`` fault poisons the drawn
        output lanes, a ``stall`` fault inflates the measured wall-clock
        (never the scheduling clock).  With no injector or no context
        the call is exactly the legacy path."""
        fault = None
        if self.injector is not None and fault_ctx is not None:
            ctx = dict(fault_ctx)
            ctx["inputs"] = padded
            fault = self.injector.draw(ctx)
            if fault is not None and fault.kind == "raise":
                from repro.serve.faults import InjectedLaunchError
                raise InjectedLaunchError(fault.reason)
        t0 = self.wall()
        inputs = [jnp.asarray(p) for p in padded]
        if device is not None:
            inputs = [jax.device_put(x, device) for x in inputs]
        res = np.asarray(fn(*inputs))
        dt = self.wall() - t0
        if fault is not None:
            if fault.kind == "nan":
                res = np.array(res)            # writable copy
                for lane in fault.lanes:
                    if 0 <= lane < res.shape[0]:
                        res[lane] = np.nan
            elif fault.kind == "stall":
                dt += fault.stall
        return res, dt

    def observe_launch(self, spec, variant, key: tuple, lanes: int,
                       measured: float, mesh: int = 1) -> None:
        """Per-launch feedback hook: called after every measured launch
        with the dispatched variant, the bucket key, the full padded
        lane width, and the measured wall-clock seconds (plus the shard
        count for mesh-spanning launches; the single-device path never
        passes ``mesh``, so legacy 5-arg overrides keep working).  The
        base engine does nothing; cost-model-carrying engines override
        it to feed :meth:`repro.serve.cost.CostModel.observe`."""

    # ---------------- batch lifecycle ----------------

    def dispatch_group(self, spec, fn, key: tuple, jobs: list,
                       variant=None, mesh: int = 1, shard: int = 0,
                       device=None) -> list:
        """The one lane-group batch lifecycle, shared by every solver
        engine: stack per-arg, pad to the pool from the (variant's or
        spec's) filler, launch ``fn`` once (measured — the wall-clock is
        stamped on the LaunchRecord and fed to :meth:`observe_launch`),
        scatter per-lane results back onto the jobs, and account the
        launch + per-job latencies.

        ``fn`` is the jit'd entry point the caller resolved through
        ``KernelSpec.dispatch_key`` for this shape bucket; ``variant``
        is the matching registry Variant (None = the spec's base).

        ``mesh > 1`` runs a mesh-spanning launch: ``fn`` must be the
        shard_map-wrapped entry point and the group is padded to the
        full ``lanes * mesh`` width, so every shard executes a complete
        ``lanes``-wide slab (no shard ever sees a partial remainder).
        ``shard``/``device`` place a non-spanning launch on one mesh
        shard; both default to the legacy single-device behavior."""
        width = self.lanes * max(1, mesh)
        stacked = [np.stack([np.asarray(j.args[i]) for j in jobs])
                   for i in range(len(jobs[0].args))]
        padded, pad = pad_group(spec, stacked, width, variant=variant)
        res, measured = self._timed_call(fn, padded, device=device)
        self.record_launch(spec.name, key, len(jobs), pad,
                           variant.name if variant is not None else "base",
                           measured=measured, mesh=mesh, shard=shard)
        if mesh > 1:
            self.observe_launch(spec, variant, key, len(jobs) + pad,
                                measured, mesh=mesh)
        else:
            # legacy call shape: mesh=1 overrides predating the mesh
            # path (5-arg signatures) keep working unmodified
            self.observe_launch(spec, variant, key, len(jobs) + pad,
                                measured)
        for i, job in enumerate(jobs):
            job.out = res[i]
            if hasattr(job, "state"):
                job.state = "done"
            self.record_job(spec.name, job)
        return jobs


class FifoEngineCore(EngineCore):
    """EngineCore plus the single-FIFO queue lifecycle: submitted items
    are stamped with ``submitted_at`` and popped oldest-first a lane
    pool at a time."""

    def __init__(self, lanes: int, clock=None):
        super().__init__(lanes, clock=clock)
        self._queue: list = []

    def submit(self, item):
        if getattr(item, "submitted_at", None) is None:
            item.submitted_at = self.clock()
        self._queue.append(item)
        return item

    def pending(self) -> int:
        return len(self._queue)

    def take(self, k: int | None = None) -> list:
        """Pop the oldest ``k`` (default: one lane pool) queued items."""
        k = self.lanes if k is None else k
        taken, self._queue = self._queue[:k], self._queue[k:]
        return taken

    def drain(self) -> list:
        return self.take(len(self._queue))


def pad_group(spec, stacked: list[np.ndarray], lanes: int, variant=None
              ) -> tuple[list[np.ndarray], int]:
    """Pad a stacked arg group's batch dim up to a multiple of ``lanes``
    using the spec's (or the dispatched variant's) declared benign filler.

    ``stacked`` holds one batched array per kernel argument.  Returns the
    padded arrays and the pad count.  Raises if padding is needed but no
    filler is declared — padding semantics are the kernel's to declare,
    not the engine's to guess (the old "square 3-D arg ⇒ add identity"
    heuristic is exactly what this replaces).  A variant with its own
    calling convention (e.g. split-complex MMSE's 4 planes) declares its
    own filler; variants that only change the execution schedule inherit
    the spec's.
    """
    b = stacked[0].shape[0]
    pad = (-b) % lanes
    if pad == 0:
        return stacked, 0
    filler = spec.filler
    if variant is not None and variant.filler is not None:
        filler = variant.filler
    if filler is None:
        raise ValueError(
            f"pipeline {spec.name!r} declares no padding filler; cannot "
            f"pad a {b}-job group to the {lanes}-lane pool")
    lane = filler(tuple(a.shape[1:] for a in stacked),
                  tuple(a.dtype for a in stacked))
    if len(lane) != len(stacked):
        raise ValueError(
            f"{spec.name!r} filler returned {len(lane)} arrays for "
            f"{len(stacked)} kernel args")
    out = []
    for arr, fill in zip(stacked, lane):
        fill = np.asarray(fill, dtype=arr.dtype)
        if fill.shape != arr.shape[1:]:
            raise ValueError(
                f"{spec.name!r} filler shape {fill.shape} != per-lane "
                f"shape {arr.shape[1:]}")
        reps = np.broadcast_to(fill, (pad,) + fill.shape)
        out.append(np.concatenate([arr, reps], axis=0))
    return out, pad
