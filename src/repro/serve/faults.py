"""Seeded fault injection for the serving stack: :class:`FaultInjector`.

Production serving has to survive the launches that DON'T come back: a
``pallas_call`` that raises, a lane that returns NaN, a launch whose
wall-clock spikes, a device that silently stops answering.  The
supervision machinery that contains those failures (retry/bisect in
:class:`repro.serve.mux.SolverMux`, shard quarantine in
:class:`repro.serve.shard.LaneShards`, the variant demotion ladder in
:class:`repro.serve.solver.VariantDispatcher`) is only trustworthy if it
can be exercised deterministically — which is what this module provides.

``FaultInjector`` sits on the one seam every launch already goes through
(:meth:`repro.serve.core.EngineCore._timed_call`): before/after each
attempt it may

  * **raise** — the launch dies with :class:`InjectedLaunchError`
    *before* the kernel executes (so chaos replays stay fast);
  * **nan** — poison specific output lanes with NaN (a sick lane the
    supervisor must isolate without sinking its group);
  * **stall** — inflate the measured wall-clock (feeds the predicted-
    cost watchdog and the drift loop, never the scheduling clock);
  * **blackhole** — a specific shard fails every launch placed on it
    (and every mesh-spanning launch) for a clock-time window — the
    scenario quarantine + probe-based reinstatement is judged by.

Faults are drawn from a committed JSON **fault trace** plus a seed:
every attempt gets its own ``np.random.default_rng([seed, attempt])``
stream, so a replay of the same trace produces the identical fault
sequence — chaos runs are golden-file-pinnable exactly like the
overload traces.  With no trace configured (the default) the injector
is never constructed and every serving path is bit-identical to the
uninjected stack.

Fault-trace JSON schema (all fields optional)::

    {
      "seed": 7,                  // overrides the constructor seed
      "launch_fail_rate": 0.1,    // P(attempt raises)
      "nan_rate": 0.08,           // P(attempt returns a poisoned lane)
      "nan_lanes": 1,             // lanes poisoned per nan fault
      "stall_rate": 0.0,          // P(measured wall-clock spikes)
      "stall_s": 0.02,            // spike size (seconds)
      "raise_on_nonfinite_input": false,  // NaN input lane crashes the
                                          // kernel (bisect-isolation
                                          // scenario)
      "blackhole": [{"shard": 2, "from_t": 0.0, "until_t": 6.0}],
      "target": [{"pipeline": "cholesky_solve", "variant": "blocked",
                  "kind": "raise", "count": 4}]
    }

``target`` entries fire deterministically on the first ``count``
attempts matching (pipeline, variant) — the lever that forces a variant
demotion; rate-based faults redraw per attempt, so retries can succeed.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.serve.config import global_config


class InjectedLaunchError(RuntimeError):
    """A launch failure manufactured by :class:`FaultInjector` — raised
    at the ``_timed_call`` seam before the kernel executes, so the
    supervisor sees exactly what a real raising ``pallas_call`` looks
    like without paying for one."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One drawn fault: ``kind`` in {"raise", "nan", "stall"}; ``reason``
    is the structured failure reason surfaced in retry/fail events;
    ``lanes`` the output lanes a nan fault poisons; ``stall`` the
    seconds a stall fault adds to the measured wall-clock."""

    kind: str
    reason: str
    lanes: tuple[int, ...] = ()
    stall: float = 0.0


class FaultInjector:
    """Deterministic, seed-keyed launch-fault source (module docstring).

    ``trace`` is the parsed fault-trace dict (see the schema above);
    ``seed`` keys the per-attempt rng streams (a ``seed`` in the trace
    wins).  ``enabled=False`` makes :meth:`draw` always return None —
    the injector can be threaded everywhere and switched off without
    touching behavior.
    """

    def __init__(self, trace: dict | None = None, seed: int = 0,
                 enabled: bool = True):
        trace = dict(trace or {})
        self.seed = int(trace.get("seed", seed))
        self.enabled = bool(enabled)
        self.launch_fail_rate = float(trace.get("launch_fail_rate", 0.0))
        self.nan_rate = float(trace.get("nan_rate", 0.0))
        self.nan_lanes = max(1, int(trace.get("nan_lanes", 1)))
        self.stall_rate = float(trace.get("stall_rate", 0.0))
        self.stall_s = float(trace.get("stall_s", 0.0))
        self.raise_on_nonfinite_input = bool(
            trace.get("raise_on_nonfinite_input", False))
        self.blackhole = [dict(b) for b in trace.get("blackhole", ())]
        # mutable remaining-count copies: the injector owns its trace
        self.target = [dict(t) for t in trace.get("target", ())]
        self.attempt = 0            # global attempt counter (rng key)

    # ---------------- construction ----------------

    @classmethod
    def from_json(cls, path: str, seed: int = 0) -> "FaultInjector":
        with open(path) as f:
            return cls(json.load(f), seed=seed)

    @classmethod
    def from_config(cls, config=None) -> "FaultInjector | None":
        """The env-driven default: an injector loaded from
        ``REPRO_SERVE_FAULT_TRACE`` (seeded by
        ``REPRO_SERVE_FAULT_SEED``), or None when no trace is configured
        — the golden-trace-deterministic default."""
        config = config if config is not None else global_config
        path = getattr(config, "fault_trace", "")
        if not path:
            return None
        return cls.from_json(path, seed=getattr(config, "fault_seed", 0))

    # ---------------- the draw ----------------

    def _blackholed(self, ctx: dict) -> bool:
        """True when the attempt touches a blackholed shard inside its
        outage window: a placed launch on that shard, or any mesh-
        spanning launch (which occupies every shard)."""
        t = float(ctx.get("t", 0.0))
        shard = ctx.get("shard")
        mesh = int(ctx.get("mesh", 1))
        for b in self.blackhole:
            if not (float(b.get("from_t", 0.0)) <= t
                    < float(b.get("until_t", np.inf))):
                continue
            if mesh > 1 or (shard is not None
                            and int(b["shard"]) == int(shard)):
                return True
        return False

    def _targeted(self, ctx: dict) -> dict | None:
        for entry in self.target:
            if entry.get("count", 0) <= 0:
                continue
            if entry.get("pipeline") not in (None, ctx.get("pipeline")):
                continue
            if entry.get("variant") not in (None, ctx.get("variant")):
                continue
            entry["count"] -= 1
            return entry
        return None

    def draw(self, ctx: dict) -> Fault | None:
        """Draw the fault (or None) for one launch attempt.  ``ctx``
        carries the attempt's identity: ``pipeline``, ``variant``,
        ``width`` (padded lane count), ``mesh``, ``shard`` (placed shard
        or None), ``t`` (scheduling-clock time), and optionally
        ``inputs`` (the padded arrays, for the nonfinite-input trigger).

        Every call consumes one attempt index whether or not a fault
        fires, so the rate-based stream is a fixed function of (seed,
        attempt order) — replays are bit-identical."""
        if not self.enabled:
            return None
        idx = self.attempt
        self.attempt += 1
        if self._blackholed(ctx):
            return Fault("raise", reason="blackhole")
        if self.raise_on_nonfinite_input:
            inputs = ctx.get("inputs") or ()
            if any(not np.all(np.isfinite(np.asarray(a)))
                   for a in inputs):
                return Fault("raise", reason="nonfinite_input_crash")
        hit = self._targeted(ctx)
        if hit is not None:
            kind = hit.get("kind", "raise")
            if kind == "nan":
                lane = int(hit.get("lane", 0))
                return Fault("nan", reason="targeted_nan", lanes=(lane,))
            if kind == "stall":
                return Fault("stall", reason="targeted_stall",
                             stall=float(hit.get("stall_s",
                                                 self.stall_s)))
            return Fault("raise", reason="targeted_fault")
        if not (self.launch_fail_rate or self.nan_rate
                or self.stall_rate):
            return None
        rng = np.random.default_rng([self.seed, idx])
        u = float(rng.random())
        if u < self.launch_fail_rate:
            return Fault("raise", reason="injected_fault")
        u -= self.launch_fail_rate
        if u < self.nan_rate:
            width = max(1, int(ctx.get("width", 1)))
            k = min(self.nan_lanes, width)
            lanes = tuple(int(x) for x in
                          rng.choice(width, size=k, replace=False))
            return Fault("nan", reason="injected_nan", lanes=lanes)
        u -= self.nan_rate
        if u < self.stall_rate:
            return Fault("stall", reason="injected_stall",
                         stall=self.stall_s)
        return None
